"""EMA three-sketch framework (paper §4.1, Eqs. 5a-5c).

Per layer we maintain three complementary sketches of the (transposed)
batch activation matrix  A^T ∈ R^{d x Nb}:

    X_s ∈ R^{d x k}   input/co-range sketch   X <- beta X + (1-beta) A_prev^T Υ
    Y_s ∈ R^{d x k}   output/range sketch     Y <- beta Y + (1-beta) A^T Ω
    Z_s ∈ R^{d x s}   interaction/core sketch Z <- beta Z + (1-beta) (A^T Φ) ⊙ Ψ^T

with k = s = 2r+1 for target rank r.  Υ, Ω ∈ R^{Nb x k} and Φ ∈ R^{Nb x s}
are random Gaussian projections shared across layers; Ψ^[l] ∈ R^s is a
layer-specific weight vector.

JAX adaptation (DESIGN.md §1): buffers are allocated at k_max = 2 r_max + 1
and the *active* rank r_t is runtime state — columns >= k_active are masked.
This keeps every shape static so `jit` never recompiles on a rank change;
a rank change merely updates the mask and re-derives the projections via
`jax.random.fold_in(key, epoch_of_change)`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.sketches.update import (          # noqa: F401  (re-exported:
    active_mask, ema_triple_update, mask_columns,  # the masking helpers
)                                            # historically lived here)

Array = jax.Array


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SketchConfig:
    """Static configuration of the sketching framework."""

    rank: int = 2                   # initial target rank r0
    max_rank: int = 16              # r_max: buffers sized k_max = 2*r_max+1
    beta: float = 0.95              # EMA momentum
    batch_size: int = 128           # Nb — rows of the projection matrices
    dtype: Any = jnp.float32        # sketch arithmetic dtype
    # reconstruction: 'faithful' = paper Eqs 6-7 with pinv;
    # 'fast' = ridge-regularized normal-equation solves (TPU-friendly).
    recon_mode: str = "faithful"
    ridge: float = 1e-4             # RELATIVE ridge for 'fast' solves
    # projection family (DESIGN.md §13): "gaussian" = dense (Nb, k_max)
    # matrices; "psparse" = seeds-only p-sparsified projections
    proj_kind: str = "gaussian"
    proj_density: float = 0.1       # psparse nonzero fraction p

    def __post_init__(self):
        from repro.sketches.psparse import validate_proj_kind
        validate_proj_kind(self.proj_kind)

    @property
    def k0(self) -> int:
        return 2 * self.rank + 1

    @property
    def k_max(self) -> int:
        return 2 * self.max_rank + 1

    def k_of(self, r) -> Array | int:
        """k = 2r+1 (works on traced r)."""
        return 2 * r + 1


# ---------------------------------------------------------------------------
# State pytrees
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Projections:
    """Random Gaussian projection matrices (paper §4.1).

    Upsilon/Omega/Phi are shared across layers; Psi is per-layer
    (stacked along a leading L axis).
    """

    upsilon: Array   # (Nb, k_max)
    omega: Array     # (Nb, k_max)
    phi: Array       # (Nb, k_max)        (s = k in the paper: k = s = 2r+1)
    psi: Array       # (L, k_max)         layer-specific interaction weights


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SketchState:
    """EMA sketches for a stack of L uniform layers + adaptive-rank scalars."""

    x: Array         # (L, d, k_max)  input sketch  X_s
    y: Array         # (L, d, k_max)  output sketch Y_s
    z: Array         # (L, d, k_max)  interaction sketch Z_s
    proj: Projections
    rank: Array      # ()  int32 — active target rank r_t
    key: Array       # PRNG key the projections were derived from
    epoch: Array     # () int32 — fold_in counter for projection refresh
    step: Array      # () int32 — EMA update counter (for bias-correction)

    @property
    def k_active(self) -> Array:
        return 2 * self.rank + 1


def _gaussian(key: Array, shape, dtype) -> Array:
    return jax.random.normal(key, shape, dtype=dtype)


def make_projections(
    key: Array, cfg: SketchConfig, num_layers: int
) -> Projections:
    ku, ko, kp, ks = jax.random.split(key, 4)
    d = cfg.dtype
    return Projections(
        upsilon=_gaussian(ku, (cfg.batch_size, cfg.k_max), d),
        omega=_gaussian(ko, (cfg.batch_size, cfg.k_max), d),
        phi=_gaussian(kp, (cfg.batch_size, cfg.k_max), d),
        psi=_gaussian(ks, (num_layers, cfg.k_max), d),
    )


def init_sketch_state(
    key: Array, cfg: SketchConfig, num_layers: int, width: int
) -> SketchState:
    """Zero sketches + fresh projections (paper Alg. 1 lines 1-3)."""
    proj = make_projections(key, cfg, num_layers)
    zeros = jnp.zeros((num_layers, width, cfg.k_max), cfg.dtype)
    return SketchState(
        x=zeros,
        y=zeros,
        z=zeros,
        proj=proj,
        rank=jnp.asarray(cfg.rank, jnp.int32),
        key=key,
        epoch=jnp.asarray(0, jnp.int32),
        step=jnp.asarray(0, jnp.int32),
    )


# ---------------------------------------------------------------------------
# EMA updates (paper Eqs. 5a-5c) — thin wrappers over the ONE canonical
# implementation in repro.sketches.update (single layer / stacked forms)
# ---------------------------------------------------------------------------


def sketch_update_single(
    x_s: Array,
    y_s: Array,
    z_s: Array,
    a_prev: Array,     # (Nb, d_in)  activations entering the layer
    a_out: Array,      # (Nb, d_out) activations leaving the layer
    proj: Projections,
    layer_idx,
    beta: float,
    k_active: Array,
) -> tuple[Array, Array, Array]:
    """One EMA sketch update for one layer (layer-indexed legacy form:
    X observes a_prev, Y/Z observe a_out). Delegates to
    `sketches.ema_triple_update`; `repro.kernels.ref.sketch_update_ref`
    is the kernel oracle for the node-indexed (a_prev == a_out) case.
    """
    return ema_triple_update(
        x_s, y_s, z_s, a_prev,
        proj.upsilon, proj.omega, proj.phi, proj.psi[layer_idx],
        beta, k_active, a_out=a_out, use_kernel=False)


def sketch_update_stack(
    state: SketchState,
    acts: Array,       # (L+1, Nb, d) — activation trajectory A^[0..L]
    beta: float,       # SketchConfig.beta — callers must thread it
) -> SketchState:
    """Update all L layers' sketches from the full activation trajectory.

    Layer l's input sketch consumes acts[l], output sketches consume
    acts[l+1] (paper: X uses A^[l-1], Y/Z use A^[l]). vmaps the canonical
    `sketches.ema_triple_update` over the layer stack.

    `beta` is required: pass `SketchConfig.beta` explicitly (an earlier
    revision silently substituted 0.95 when it was omitted, which let a
    config's beta diverge from the update actually applied).
    """
    k_act = state.k_active
    a_prev = acts[:-1]
    a_out = acts[1:]
    new = jax.vmap(
        lambda xs, ys, zs, ap, ao, psi: ema_triple_update(
            xs, ys, zs, ap, state.proj.upsilon, state.proj.omega,
            state.proj.phi, psi, beta, k_act, a_out=ao, use_kernel=False)
    )(state.x, state.y, state.z, a_prev, a_out, state.proj.psi)
    return dataclasses.replace(
        state, x=new[0], y=new[1], z=new[2], step=state.step + 1
    )


# ---------------------------------------------------------------------------
# Lemma 4.1 helper: the conceptual EMA activation matrix (tests only)
# ---------------------------------------------------------------------------


def ema_activation_matrix(act_history: list[Array], beta: float) -> Array:
    """A_EMA(n) = (1-beta) sum_j beta^{n-j} A(j)^T  — O(d*Nb), test-only.

    Lemma 4.1 asserts  X_s(n) == A_EMA(n) @ Upsilon  exactly; unit tests
    verify this to machine precision.
    """
    n = len(act_history)
    out = jnp.zeros_like(act_history[0].T)
    for j, a in enumerate(act_history, start=1):
        out = out + (1.0 - beta) * beta ** (n - j) * a.T
    return out


def refresh_projections(state: SketchState, cfg: SketchConfig) -> SketchState:
    """Re-randomize projections + zero sketches (paper Alg.1: 'reinitialize
    matrices' after a rank change). Static shapes — only values change."""
    epoch = state.epoch + 1
    key = jax.random.fold_in(state.key, epoch)
    L = state.proj.psi.shape[0]
    proj = make_projections(key, cfg, L)
    return dataclasses.replace(
        state,
        x=jnp.zeros_like(state.x),
        y=jnp.zeros_like(state.y),
        z=jnp.zeros_like(state.z),
        proj=proj,
        epoch=epoch,
        step=jnp.zeros_like(state.step),
    )


def sketch_memory_bytes(cfg: SketchConfig, num_layers: int, width: int) -> int:
    """Actual bytes held by the sketch state (for memory benchmarks).

    The projection term is the proj_kind split the memory-complexity
    gate asserts exactly (DESIGN.md §13): dense gaussian stores three
    (Nb, k_max) matrices; psparse stores a (3, 4) uint32 coefficient
    array — O(1) bytes, independent of Nb and k_max. psi (per-layer,
    k-sized) is identical in both."""
    itemsize = jnp.dtype(cfg.dtype).itemsize
    sketches = 3 * num_layers * width * cfg.k_max * itemsize
    psi = num_layers * cfg.k_max * itemsize
    if cfg.proj_kind == "psparse":
        proj = 3 * 4 * 4                      # (3, 4) uint32 seeds
    else:
        proj = 3 * cfg.batch_size * cfg.k_max * itemsize
    return sketches + psi + proj
