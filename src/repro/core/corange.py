"""Co-range sketch variant — beyond-paper correctness fix (DESIGN.md §1).

The paper's neural adaptation (Eqs. 5a-5c) right-multiplies the transposed
activation by batch projections, so all three sketches live in FEATURE
space: the batch-side co-range of A_EMA^T is never observed and the
psi/Upsilon scalings are never inverted. Its reconstruction (Eqs. 6-7) is
therefore a heuristic "learned projection" (the paper's own words) and the
sqrt(6)-tail bound of Theorem 4.2 does not literally transfer — which is
consistent with the paper's empirical 3-5% accuracy gap.

This module implements the ORIGINAL control-theoretic three-sketch
[Tropp et al. 2017; Muthukumar-Kouri-Udell 2021] applied to the EMA
activation matrix M := A_EMA^T (d x N_b), at the same memory cost:

    X_c = Upsilon_c @ M           (k x N_b)   co-range sketch
    Y_c = M @ Omega_c             (d x k)     range sketch
    Z_c = Phi_c @ M @ Psi_c       (s x s)     core sketch

All three are linear in M, so the EMA property (Lemma 4.1) holds verbatim.
Reconstruction follows the source framework exactly:

    X_c^T = P R1 ;  Y_c = Q R2
    C = (Phi_c Q)^+  Z_c  ((Psi_c^T P)^+)^T
    M~ = Q C P^T                   with  E||M - M~||_F <= sqrt(6) tau_{r+1}(M)

Tests verify the bound numerically; the LM sketch context and the MLP
trainer can select recon="corange" to train with provably-bounded
gradient reconstruction.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.reconstruct import Reconstruction, masked_qr
from repro.core.sketch import mask_columns
from repro.sketches.update import corange_triple_update

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CorangeProjections:
    upsilon: Array    # (k_max, d)    feature-space co-range projection
    omega: Array      # (N_b, k_max)  batch-space range projection
    phi: Array        # (s_max, d)    core left projection
    psi: Array        # (N_b, s_max)  core right projection


def s_of(k: int) -> int:
    """Core-sketch dim: s = 2k + 1 (Tropp's stability requirement)."""
    return 2 * k + 1


def make_corange_projections(key, d: int, n_b: int, k_max: int,
                             dtype=jnp.float32) -> CorangeProjections:
    ks = jax.random.split(key, 4)
    s_max = s_of(k_max)
    g = lambda k, shape: jax.random.normal(k, shape, dtype=dtype)
    return CorangeProjections(
        upsilon=g(ks[0], (k_max, d)),
        omega=g(ks[1], (n_b, k_max)),
        phi=g(ks[2], (s_max, d)),
        psi=g(ks[3], (n_b, s_max)),
    )


def corange_update(
    x_c: Array,        # (k_max, N_b)
    y_c: Array,        # (d, k_max)
    z_c: Array,        # (s_max, s_max), s = 2k+1
    a: Array,          # (N_b, d) current batch activations
    proj: CorangeProjections,
    beta: float,
    k_active,
) -> tuple[Array, Array, Array]:
    """EMA update of the Tropp triple against M_batch = a^T — delegates
    to the canonical implementation in `repro.sketches.update`."""
    return corange_triple_update(x_c, y_c, z_c, a, proj, beta, k_active)


def corange_reconstruct(
    x_c: Array, y_c: Array, z_c: Array,
    proj: CorangeProjections,
    k_active,
    *,
    ridge: float = 1e-8,
) -> Reconstruction:
    """M~ = Q C P^T; returns A~ = M~^T factored as left @ right^T with
    left = P (N_b, k), right = Q C^T (d, k)."""
    dt = jnp.promote_types(x_c.dtype, jnp.float32)
    x_c = x_c.astype(dt)
    y_c = y_c.astype(dt)
    z_c = z_c.astype(dt)
    s_active = 2 * k_active + 1
    p = masked_qr(x_c.T, k_active)                 # (N_b, k)
    q = masked_qr(y_c, k_active)                   # (d, k)
    phi_q = mask_columns(proj.phi.astype(dt).T, s_active).T @ q    # (s, k)
    psi_p = mask_columns(proj.psi.astype(dt), s_active).T @ p      # (s, k)
    c1 = jnp.linalg.pinv(phi_q) @ z_c              # (k, s)
    c = c1 @ jnp.linalg.pinv(psi_p).T              # (k, k)
    # A~ = M~^T = P C^T Q^T = left @ right^T
    return Reconstruction(left=p, right=q @ c)


def corange_reconstruct_batched(
    x_c: Array,        # (L, k_max, N_b) stacked co-range sketches
    y_c: Array,        # (L, d, k_max)
    z_c: Array,        # (L, s_max, s_max)
    proj: CorangeProjections,
    k_active,
    *,
    ridge: float = 1e-8,
) -> Reconstruction:
    """One BATCHED reconstruction over a stacked corange SketchNode —
    the vmap of `corange_reconstruct` with the shared projections held
    constant. All L layers' QR/pinv solves lower as single batched
    linalg calls, so a jaxpr of the MLP corange forward traces exactly
    ONE reconstruct computation instead of L (asserted in
    tests/test_reconstruct.py). Returns Reconstruction with left
    (L, N_b, k) / right (L, d, k)."""
    return jax.vmap(
        lambda xc, yc, zc: corange_reconstruct(
            xc, yc, zc, proj, k_active, ridge=ridge)
    )(x_c, y_c, z_c)
