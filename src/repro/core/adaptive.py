"""Adaptive rank controller (paper §4.3, Algorithm 1).

Patience-driven: sustained improvement -> shrink r (save memory);
stagnation -> grow r (higher fidelity); growth past tau_reset -> reset to
r0. Every rank change "reinitializes matrices" (paper) — here that is a
masked, shape-static operation: sketches zero, projections re-derived via
fold_in, `rank` scalar updated; `jit` never recompiles.

The controller is pure scalar arithmetic (jnp.where, no host callbacks) so
it runs inside the jitted train step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    r0: int = 2
    r_min: int = 1
    r_max: int = 16
    patience_decrease: int = 3       # epochs of improvement -> shrink
    patience_increase: int = 5       # epochs of stagnation  -> grow
    dr_down: int = 1
    dr_up: int = 2
    tau_reset: int = 14              # r + dr_up >= tau -> reset to r0
    min_delta: float = 1e-4          # relative improvement threshold


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdaptiveState:
    best_metric: Array       # () f32 best (lowest) metric seen
    streak_improve: Array    # () i32 consecutive improving epochs
    streak_stall: Array      # () i32 consecutive stalled epochs
    num_changes: Array       # () i32 rank changes so far (diagnostics)


def init_adaptive_state() -> AdaptiveState:
    return AdaptiveState(
        best_metric=jnp.asarray(jnp.inf, jnp.float32),
        streak_improve=jnp.asarray(0, jnp.int32),
        streak_stall=jnp.asarray(0, jnp.int32),
        num_changes=jnp.asarray(0, jnp.int32),
    )


def adaptive_step(
    state: AdaptiveState,
    rank: Array,              # () i32 current r
    metric: Array,            # () f32 epoch metric (lower is better)
    cfg: AdaptiveConfig,
) -> tuple[AdaptiveState, Array, Array]:
    """One per-epoch controller update.

    Returns (new_state, new_rank, changed) where `changed` is a bool
    scalar — the caller zeroes sketches + folds the projection key when
    it is True (paper: "reinitialize matrices").
    """
    improved = metric < state.best_metric * (1.0 - cfg.min_delta)
    streak_improve = jnp.where(improved, state.streak_improve + 1, 0)
    streak_stall = jnp.where(improved, 0, state.streak_stall + 1)

    do_down = streak_improve >= cfg.patience_decrease
    do_up = streak_stall >= cfg.patience_increase

    r_down = jnp.maximum(cfg.r_min, rank - cfg.dr_down)
    grown = rank + cfg.dr_up
    r_up = jnp.where(grown >= cfg.tau_reset, cfg.r0,
                     jnp.minimum(grown, cfg.r_max))

    new_rank = jnp.where(do_down, r_down, jnp.where(do_up, r_up, rank))
    changed = new_rank != rank

    new_state = AdaptiveState(
        best_metric=jnp.minimum(state.best_metric, metric),
        streak_improve=jnp.where(do_down | do_up, 0, streak_improve),
        streak_stall=jnp.where(do_down | do_up, 0, streak_stall),
        num_changes=state.num_changes + changed.astype(jnp.int32),
    )
    return new_state, new_rank.astype(jnp.int32), changed
