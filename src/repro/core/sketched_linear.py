"""Back-compat shim — the sketched linear layer moved to
``repro.sketches.linear`` and the canonical per-node EMA update to
``repro.sketches.update`` (DESIGN.md §6).

``ema_node_update`` is the node-indexed form of the paper's Eqs. 5a-5c
(the triple observes the tensor that feeds the layer); it is kept here
only as a name alias so historical imports keep working.
"""
from __future__ import annotations

import jax

from repro.sketches.linear import sketched_matmul  # noqa: F401
from repro.sketches.update import ema_triple_update

Array = jax.Array


def ema_node_update(
    x_s: Array, y_s: Array, z_s: Array,
    a: Array,              # (T, d) the node's activation (will be stop_grad)
    upsilon: Array,        # (T, k_max)
    omega: Array,          # (T, k_max)
    phi: Array,            # (T, k_max)
    psi: Array,            # (k_max,) layer-specific weights
    beta: float,
    k_active: Array,
) -> tuple[Array, Array, Array]:
    return ema_triple_update(
        x_s, y_s, z_s, a, upsilon, omega, phi, psi, beta, k_active)
