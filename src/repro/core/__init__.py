# The paper's primary contribution: EMA three-sketch activation
# compression with reconstruction-based sketched backprop, adaptive rank,
# and sketch-derived gradient monitoring.
from repro.core.sketch import (
    SketchConfig, SketchState, Projections,
    init_sketch_state, make_projections, sketch_update_single,
    sketch_update_stack, ema_activation_matrix, refresh_projections,
    active_mask, mask_columns, sketch_memory_bytes,
)
from repro.core.reconstruct import (
    Reconstruction, reconstruct, reconstruct_dense_faithful, masked_qr,
)
from repro.core.sketched_linear import sketched_matmul, ema_node_update
from repro.core.adaptive import (
    AdaptiveConfig, AdaptiveState, init_adaptive_state, adaptive_step,
)
from repro.core.monitor import (
    MonitorState, init_monitor_state, monitor_record, stack_metrics,
    layer_metrics, stable_rank, detect_pathologies, PathologyThresholds,
    monitor_memory_bytes, tree_metrics, METRIC_NAMES, N_METRICS,
)
from repro.core.bounds import (
    tail_energy, reconstruction_bound, gradient_bound, SQRT6,
)
