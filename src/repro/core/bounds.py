"""Approximation-quality bounds (paper §4.5, Theorems 4.2 / 4.3)."""
from __future__ import annotations

import jax.numpy as jnp

SQRT6 = 6.0 ** 0.5


def tail_energy(a, r: int):
    """tau_{r+1}(A) = sqrt(sum_{i>r} sigma_i^2)."""
    s = jnp.linalg.svd(a.astype(jnp.float32), compute_uv=False)
    return jnp.sqrt(jnp.sum(s[r:] ** 2))


def reconstruction_bound(a_ema, r: int):
    """Theorem 4.2: E||A_EMA - A~_EMA||_F <= sqrt(6) tau_{r+1}(A_EMA)."""
    return SQRT6 * tail_energy(a_ema, r)


def gradient_bound(delta, a_ema, r: int, eps_coherence: float = 0.0):
    """Theorem 4.3: ||grad - grad^||_F <=
    ||delta^T||_2 [ sqrt(6) tau_{r+1}(A_EMA) + O(eps_coherence) ]."""
    dnorm = jnp.linalg.norm(delta.astype(jnp.float32), ord=2)
    return dnorm * (SQRT6 * tail_energy(a_ema, r) + eps_coherence)
