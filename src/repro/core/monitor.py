"""Sketch-based gradient monitoring (paper §4.6, Figure 5).

All metrics are derived from the EMA sketches — no gradient matrix is
ever materialized, so memory is O(L * k * d) independent of the
monitoring window T (vs O(L * d^2 * T) for storing gradient history).

Metrics per layer:
  grad_norm_proxy   ||Z_s||_F        (gradient magnitude proxy)
  stable_rank       ||Y_s||_F^2 / ||Y_s||_2^2   (gradient diversity;
                    spectral norm from the k x k Gram eigenvalues — no SVD
                    of the d x k sketch needed)
  y_norm            ||Y_s||_F        (activation energy)

The ring buffer holds `window` steps of (L, n_metrics) readings inside
device memory; pathology detection (vanishing / exploding / stagnation /
diversity collapse) reads only the buffer.

Distributed form (DESIGN.md §4): for width-sharded sketches the same
metrics are exact under psum — squared Frobenius norms add across shards
and the Gram matrix Y^T Y (k x k) psums across the width shards. See
`gram_metrics_from_partial`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array

N_METRICS = 3
METRIC_NAMES = ("grad_norm_proxy", "stable_rank", "y_norm")


def stable_rank(y_s: Array, eps: float = 1e-30) -> Array:
    """||Y||_F^2 / ||Y||_2^2 via eigvals of the k x k Gram matrix."""
    g = y_s.T @ y_s
    fro2 = jnp.trace(g)
    spec2 = jnp.max(jnp.linalg.eigvalsh(g))
    return fro2 / jnp.maximum(spec2, eps)


def layer_metrics(x_s: Array, y_s: Array, z_s: Array) -> Array:
    """(N_METRICS,) for one layer triple."""
    return jnp.stack([
        jnp.linalg.norm(z_s),
        stable_rank(y_s),
        jnp.linalg.norm(y_s),
    ])


def stack_metrics(x: Array, y: Array, z: Array) -> Array:
    """(L, N_METRICS) for stacked (L, d, k) triples."""
    return jax.vmap(layer_metrics)(x, y, z)


def tree_metrics(tree) -> Array:
    """(N, N_METRICS) over every node of a ``sketches.NodeTree``, rows in
    ``sketches.node_paths`` order (sorted by node name, layer-major).

    Works for both sketch kinds: the metrics read only ||Z||_F, ||Y||_F
    and the k x k Gram of Y, all of which exist for paper AND corange
    triples.
    """
    mets = []
    for name in sorted(tree.nodes):
        node = tree.nodes[name]
        if node.x.ndim == 2:
            mets.append(layer_metrics(node.x, node.y, node.z)[None])
        else:
            # multi-dim stacks (per-expert nodes, (L, E, d, k)) flatten
            # to one row per stack entry — same row-major order as
            # ``node_paths`` ("block3/expert_in/7")
            x, y, z = (a.reshape((-1,) + a.shape[-2:])
                       for a in (node.x, node.y, node.z))
            mets.append(stack_metrics(x, y, z))
    return jnp.concatenate(mets, 0)


def gram_metrics_from_partial(y_local: Array, axis_name: str) -> Array:
    """stable_rank of a width-sharded Y from local shards (exact)."""
    g = jax.lax.psum(y_local.T @ y_local, axis_name)
    fro2 = jnp.trace(g)
    spec2 = jnp.max(jnp.linalg.eigvalsh(g))
    return fro2 / jnp.maximum(spec2, 1e-30)


# ---------------------------------------------------------------------------
# Ring buffer
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MonitorState:
    buffer: Array    # (window, L, N_METRICS) f32
    idx: Array       # () i32 next write slot
    count: Array     # () i32 total writes (saturates display logic)


def init_monitor_state(window: int, num_layers: int) -> MonitorState:
    return MonitorState(
        buffer=jnp.zeros((window, num_layers, N_METRICS), jnp.float32),
        idx=jnp.asarray(0, jnp.int32),
        count=jnp.asarray(0, jnp.int32),
    )


def monitor_record(state: MonitorState, metrics: Array) -> MonitorState:
    """Write one (L, N_METRICS) reading into the ring."""
    window = state.buffer.shape[0]
    buf = jax.lax.dynamic_update_slice_in_dim(
        state.buffer, metrics[None].astype(jnp.float32), state.idx, axis=0
    )
    return MonitorState(
        buffer=buf,
        idx=jnp.mod(state.idx + 1, window),
        count=state.count + 1,
    )


def monitor_memory_bytes(window: int, num_layers: int) -> int:
    return window * num_layers * N_METRICS * 4


# ---------------------------------------------------------------------------
# Pathology detection (paper §5.3 healthy-vs-problematic demo)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PathologyThresholds:
    vanish_norm: float = 1e-5
    explode_norm: float = 1e6
    stagnation_rel: float = 1e-3     # max relative change over window
    collapse_frac: float = 0.45      # stable rank < frac * k -> collapsed
    min_fill: int = 4                # window-statistic flags stay False
    #                                  until the ring holds this many
    #                                  readings (a 1-reading buffer has
    #                                  max == min -> spurious stagnation)


def detect_pathologies(
    state: MonitorState, k_active: int,
    th: PathologyThresholds = PathologyThresholds(),
) -> dict[str, Array]:
    """Boolean (L,) flags per pathology, from the ring buffer only.

    Flags that compare statistics ACROSS the window (stagnation,
    diversity collapse) are gated until the buffer holds at least
    `th.min_fill` readings: a warming-up ring has rel_span == 0 and an
    unsettled stable-rank mean, which would otherwise flag healthy runs
    on step one. Point-in-time flags (vanishing/exploding) need no
    window warm-up, but DO need at least one reading: an EMPTY ring
    (count == 0 — a freshly-initialized serving engine polled before
    its first decode) has mean_norm == 0, which would otherwise emit a
    spurious "vanishing" on every layer (serving-warmup regression
    tests in tests/test_serve.py)."""
    buf = state.buffer                                 # (W, L, M)
    n = jnp.minimum(state.count, buf.shape[0]).astype(jnp.float32)
    n = jnp.maximum(n, 1.0)
    valid = (jnp.arange(buf.shape[0]) <
             jnp.minimum(state.count, buf.shape[0]))[:, None, None]
    norms = jnp.where(valid[..., 0], buf[..., 0], 0.0)  # grad_norm_proxy
    mean_norm = norms.sum(0) / n
    max_norm = jnp.where(valid[..., 0], buf[..., 0], -jnp.inf).max(0)
    min_norm = jnp.where(valid[..., 0], buf[..., 0], jnp.inf).min(0)
    sr = jnp.where(valid[..., 0], buf[..., 1], 0.0).sum(0) / n
    rel_span = (max_norm - min_norm) / jnp.maximum(mean_norm, 1e-30)
    has_data = state.count >= 1
    warmed = state.count >= jnp.minimum(th.min_fill, buf.shape[0])
    return {
        "vanishing": has_data & (mean_norm < th.vanish_norm),
        "exploding": has_data & (max_norm > th.explode_norm),
        "stagnating": warmed & (rel_span < th.stagnation_rel),
        "diversity_collapse": warmed & (sr < th.collapse_frac * k_active),
    }
