"""CSVec — a count-sketch of a length-`dim` vector as a JAX pytree.

The sketch is an (r hash rows x c buckets) table; element i of the
source vector lands in bucket h_j(i) of row j with sign s_j(i).  Both
hashes are MULTIPLY-SHIFT (Dietzfelbinger et al.): with a_j odd,

    h_j(i) = (a_j * i + b_j)  >>  (32 - log2 c)      (c a power of two)
    s_j(i) = 1 - 2 * ((a'_j * i + b'_j) >> 31)

All arithmetic is uint32 with natural wraparound — exactly computable
both in jnp and inside a Pallas kernel (no gather tables in HBM), so the
fused insert kernel (`repro.kernels.csvec_insert`) and this reference
agree bit-for-bit on the hash values.

Key properties (tested in tests/test_countsketch.py):
  * LINEARITY — sketch(g1 + g2) == merge(sketch(g1), sketch(g2)); the
    table is a linear image of the input, so a `psum` over the DP axis
    aggregates worker sketches exactly (unlike top-k sparsification).
  * Heavy hitters — `unsketch` recovers the top-k coordinates by
    median-of-r magnitude estimate (SketchedSGD, Ivkin et al.).

Shapes are static (dim/rows/cols fixed at construction), so every op
here composes with jit/vmap/shard_map without recompilation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.ops import segment_sum

Array = jax.Array

_U32 = jnp.uint32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CSVec:
    """Count-sketch state. `table` is the only data leaf that changes
    per step; `params` holds the (4, r) uint32 hash coefficients
    [a_bucket; b_bucket; a_sign; b_sign] derived from one PRNG key —
    workers built from the same key share hashes, which is what makes
    their tables mergeable."""

    table: Array     # (r, c) f32 — the sketch counters
    params: Array    # (4, r) u32 — multiply-shift hash coefficients
    dim: int = dataclasses.field(metadata=dict(static=True))

    @property
    def rows(self) -> int:
        return self.table.shape[0]

    @property
    def cols(self) -> int:
        return self.table.shape[1]


def _shift_for(cols: int) -> int:
    log2c = cols.bit_length() - 1
    if cols != (1 << log2c):
        raise ValueError(f"cols must be a power of two, got {cols}")
    return 32 - log2c


def make_csvec(key: Array, dim: int, rows: int, cols: int) -> CSVec:
    """Zero table + hash coefficients. `a` coefficients are forced odd
    (multiply-shift is 2-universal only for odd multipliers)."""
    _shift_for(cols)
    params = jax.random.bits(key, (4, rows), _U32)
    odd = params.at[0].set(params[0] | _U32(1)).at[2].set(
        params[2] | _U32(1))
    return CSVec(
        table=jnp.zeros((rows, cols), jnp.float32),
        params=odd,
        dim=dim,
    )


def zero_table(cs: CSVec) -> CSVec:
    return dataclasses.replace(cs, table=jnp.zeros_like(cs.table))


def hash_buckets(params: Array, cols: int, idx: Array) -> Array:
    """(r, n) int32 bucket of each index per hash row."""
    shift = _U32(_shift_for(cols))
    i = idx.astype(_U32)[None, :]
    a = params[0][:, None]
    b = params[1][:, None]
    return ((a * i + b) >> shift).astype(jnp.int32)


def hash_signs(params: Array, idx: Array) -> Array:
    """(r, n) f32 in {-1, +1} — top bit of the second hash."""
    i = idx.astype(_U32)[None, :]
    a = params[2][:, None]
    b = params[3][:, None]
    bit = ((a * i + b) >> _U32(31)).astype(jnp.float32)
    return 1.0 - 2.0 * bit


def insert(cs: CSVec, vec: Array) -> CSVec:
    """Accumulate `vec` (dim,) into the sketch (pure-jnp reference; the
    Pallas hot path is `repro.kernels.csvec_insert.csvec_insert`)."""
    return insert_at(cs, jnp.arange(cs.dim), vec)


def merge(a: CSVec, b: CSVec) -> CSVec:
    """Exact linear merge: valid iff both sketches share hash params
    (same construction key), which is the caller's contract."""
    if a.dim != b.dim or a.table.shape != b.table.shape:
        raise ValueError("CSVec merge: mismatched sketch geometry")
    return dataclasses.replace(a, table=a.table + b.table)


def query(cs: CSVec, idx: Array) -> Array:
    """Median-of-r unbiased estimate of vec[idx] (any shape of idx)."""
    flat = idx.reshape(-1)
    buckets = hash_buckets(cs.params, cs.cols, flat)         # (r, n)
    signs = hash_signs(cs.params, flat)
    est = signs * jnp.take_along_axis(cs.table, buckets, axis=1)
    return jnp.median(est, axis=0).reshape(idx.shape)


def query_all(cs: CSVec) -> Array:
    """(dim,) estimate of every coordinate. Materializes (r, dim)
    intermediates — the dense oracle; production recovery goes through
    `topk_streaming` / the Pallas kernel instead."""
    return query(cs, jnp.arange(cs.dim))


def insert_at(cs: CSVec, idx: Array, vals: Array) -> CSVec:
    """Accumulate a SPARSE vector (values `vals` at coordinates `idx`,
    zero elsewhere) into the sketch; `insert` is the dense special case
    (idx = arange(dim)). Costs O(r * nnz) — the only way to build
    sketches of D ≫ 10M vectors without an (r, D) hash pass."""
    buckets = hash_buckets(cs.params, cs.cols, idx)          # (r, n)
    signs = hash_signs(cs.params, idx)
    sv = signs * vals.astype(jnp.float32)[None, :]
    rows = jax.vmap(
        lambda s, b: segment_sum(s, b, num_segments=cs.cols)
    )(sv, buckets)
    return dataclasses.replace(cs, table=cs.table + rows)


def topk_streaming(cs: CSVec, k: int,
                   chunk: int = 16384) -> tuple[Array, Array]:
    """Top-k heavy hitters by |median estimate| WITHOUT materializing the
    (dim,) estimate vector: sweep the index space in fixed `chunk`-size
    windows, estimating each window in-register and folding it into a
    running (k,) best buffer — peak memory O(r * chunk + k).

    Returns (vals (k,) f32 signed estimates, idx (k,) i32), ordered by
    descending |estimate|. Candidate selection matches the dense
    `unsketch` oracle BIT-FOR-BIT: the running buffer always holds its
    survivors in global `lax.top_k` order (ties resolve to the smaller
    index because earlier chunks precede later ones in the merge
    concatenation), so the final index set equals
    `lax.top_k(|query_all(cs)|, k)` exactly.
    """
    k = min(k, cs.dim)
    n_chunks = -(-cs.dim // chunk)
    neg_inf = jnp.float32(-jnp.inf)

    def body(carry, start):
        bvals, bidx = carry
        idx = start + jnp.arange(chunk, dtype=jnp.int32)
        est = query(cs, idx)                                 # (chunk,)
        mag = jnp.where(idx < cs.dim, jnp.abs(est), neg_inf)
        bmag = jnp.where(bidx >= 0, jnp.abs(bvals), neg_inf)
        all_mag = jnp.concatenate([bmag, mag])
        _, pos = jax.lax.top_k(all_mag, k)
        all_val = jnp.concatenate([bvals, est])
        all_idx = jnp.concatenate([bidx, idx])
        return (all_val[pos], all_idx[pos]), None

    init = (jnp.zeros(k, jnp.float32), -jnp.ones(k, jnp.int32))
    starts = jnp.arange(n_chunks, dtype=jnp.int32) * chunk
    (vals, idx), _ = jax.lax.scan(body, init, starts)
    return vals, idx


def unsketch(cs: CSVec, k: int) -> Array:
    """Dense (dim,) vector holding the top-k heavy hitters by |estimate|
    at their estimated values, zero elsewhere. Static k → jit-stable.
    O(r * dim) peak memory — the reference/oracle path; use
    `topk_streaming` (or the Pallas `csvec_topk` kernel) when dim is
    large."""
    est = query_all(cs)
    k = min(k, cs.dim)
    _, idx = jax.lax.top_k(jnp.abs(est), k)
    return jnp.zeros(cs.dim, jnp.float32).at[idx].set(est[idx])


def table_bytes(cs: CSVec) -> int:
    """Bytes a worker puts on the wire per merge (the table only — hash
    params are derived from a shared key, never transmitted)."""
    return cs.table.size * cs.table.dtype.itemsize


# ---------------------------------------------------------------------------
# int8 wire format (DESIGN.md §9) — jnp reference; the fused Pallas
# kernel is repro.kernels.csvec_quant
# ---------------------------------------------------------------------------

QMAX = 127.0          # symmetric int8 grid: {-127..127}, no zero point


def quantize_rows(x: Array) -> tuple[Array, Array]:
    """Symmetric per-row (last-axis) int8 quantization of an arbitrary
    (..., k) array — the one grid map every int8 wire in the repo uses
    (table wire below, sketch-increment wire in sketches/wire.py).
    Returns (q int8 same shape, scale (..., 1) f32) with
    ``dequant = q * scale``. All-zero rows get scale 0 and quantize
    losslessly to zeros; rounding is round-half-to-even."""
    t = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(t), axis=-1, keepdims=True)
    scale = amax / QMAX
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(t / safe), -QMAX, QMAX)
    return q.astype(jnp.int8), scale


def dequantize_rows(q: Array, scale: Array) -> Array:
    """Inverse grid map of `quantize_rows` (keepdims scale)."""
    return q.astype(jnp.float32) * scale


def quantize_table(table: Array) -> tuple[Array, Array]:
    """Symmetric per-row int8 quantization of an (r, c) sketch table.

    Returns (q (r, c) int8, scale (r,) f32) with
    ``dequant = q * scale[:, None]``. The grid is SYMMETRIC (zero-point
    free) on purpose: a psum of W worker tables then carries no
    accumulated zero-point bias (an affine grid would add W * zp), so
    the merged estimate stays unbiased and the only quantization effect
    is bounded per-entry rounding noise — which the SketchedSGD error
    feedback absorbs (optim/sketched_sgd.py). Rounding is
    round-half-to-even to match `jnp.round` everywhere. All-zero rows
    get scale 0 and quantize losslessly to zeros.
    """
    q, scale = quantize_rows(table)
    return q, scale[:, 0]


def dequantize_table(q: Array, scale: Array) -> Array:
    """Inverse grid map: (r, c) int8 + (r,) f32 -> (r, c) f32."""
    return q.astype(jnp.float32) * scale[:, None]


def quantize_residual(table: Array, q: Array, scale: Array) -> Array:
    """The per-entry quantization error ``table - dequant(q, scale)``.

    By construction ``dequant + residual == table`` exactly in f32
    (it is literally a subtract-then-add of the same value — the
    mass-exactness property the hypothesis suite asserts). The residual
    stays WORKER-LOCAL: the transmitted update is reconstructed from
    quantized tables only, so ``v_new = v_pre - update`` retains the
    full quantization error in the error-feedback accumulator and
    re-sends it on a later step.
    """
    return table.astype(jnp.float32) - dequantize_table(q, scale)


def quantized_table_bytes(cs: CSVec) -> int:
    """int8 wire cost of one table merge: 1 byte per counter plus the
    (r,) f32 per-row scales."""
    return cs.table.size * 1 + cs.rows * 4
