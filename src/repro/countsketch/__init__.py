# Count-sketch gradient compression: a LINEAR sketch (unlike top-k), so
# per-worker sketches aggregate exactly under psum — the mergeable
# collective the DP axis needs (DESIGN: ISSUE 1).
from repro.countsketch.csvec import (
    CSVec, make_csvec, zero_table, insert, insert_at, query, query_all,
    merge, unsketch, topk_streaming, table_bytes, hash_buckets,
    hash_signs,
)
